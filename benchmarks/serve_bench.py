"""Serving-runtime benchmark: SAGA vs request-level on REAL inference.

Drives the event-driven concurrent runtime (``repro.serving.runtime``)
with a trace-driven agent mix (SWE-bench / WebArena / BurstGPT-style
structures from ``cluster.workload.runtime_requests``) over multiple
real engines — actual jitted forward passes on the micro model, CPU —
and compares workflow-atomic SAGA against the request-level baseline
(vLLM-v0.6.0-style: KV discarded between steps):

  * task-completion time (virtual clock: queueing + prefill + decode +
    tool gaps),
  * regenerated prefill tokens (the paper's central quantity, measured
    from the engines' own counters, not simulated),
  * conservation (every session finishes; no leaked slots or blocks).

The request-level pass REUSES the SAGA pass's engines (their jit caches
are warm and their pools were conservation-checked empty), so the A/B
costs one compile set; its regeneration is the engine-counter delta.

    PYTHONPATH=src:. python benchmarks/serve_bench.py           # full
    PYTHONPATH=src:. python benchmarks/serve_bench.py --smoke   # CI gate

The smoke gate additionally asserts:

  * **chaos mode** — the same SAGA run under a ``cluster.faults``
    chaos plan (engine fail/recover/scale-up mid-decode, cancellation
    through the attempt-stamped registry): conservation + zero slot/KV
    leak must hold on real engines, same as the simulator;
  * **preemption A/B** — a two-tenant starvation scenario where
    SAGA-with-preemption must preempt at least one running decode and
    show strictly lower max AFS deviation (Thm. 2) than admission-only
    ordering;
  * **paged-vs-gather A/B** — the true-paged decode path (attend over
    pool block tables, metadata-only park/resume) against the gather
    oracle: byte-identical summaries, identical regeneration, zero
    park/resume device-copy bytes in paged mode (vs real copies in
    gather), with the per-decode-round latency delta reported;
  * byte-identical SAGA summaries (clean + chaos + preemption) for two
    identical-seed runs in-process AND across processes with different
    PYTHONHASHSEED (the runtime's determinism contract), with the
    fingerprint written to ``benchmarks/results/`` for CI to diff
    against the committed ``benchmarks/expected/`` twin;
  * **disaggregation A/B** — the same BurstGPT-style burst mix over 8
    engines, unified vs prefill/decode-disaggregated pools (both arms
    under chunked-prefill interference): disagg must improve
    TTFT-on-resume p99 (speculative prefill + handoff overlap the tool
    gap) without degrading p99 decode-round latency, conserve, and its
    own fingerprint (clean + prefill-engine-death chaos) is diffed
    against ``benchmarks/expected/serve_bench_disagg_fingerprint.txt``.

CSV rows follow the house format: ``name,us_per_call,derived``.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax
import numpy as np

from repro.cluster.faults import chaos_plan
from repro.cluster.workload import runtime_requests
from repro.configs import get_config, load_all
from repro.core.coordinator import SAGAConfig
from repro.models import lm
from repro.serving.disagg import ROLE_DECODE, ROLE_PREFILL
from repro.serving.runtime import (AgentRequest, RuntimePerf,
                                   ServingRuntime)

from repro.obs.export import chrome_trace, report

from benchmarks.common import (emit, percentile, save_fingerprint,
                               save_json)

N_WORKERS = 2
N_SLOTS = 6
MAX_LEN = 256
POOL_BLOCKS = 144
SEED = 0
DISAGG_WORKERS = 8
# runtime_requests scales token counts down 64x to fit the micro model;
# the virtual prefill rate scales with them (8000 tok/s at 70B / 64) so
# regeneration costs the same *fraction* of virtual time as at scale.
# Decode needs no rescale: one round is one token per session either way.
PERF = RuntimePerf(prefill_tokens_per_s=8000.0 / 64.0)

ENGINE_KEYS = ("prefill_tokens", "regen_tokens", "decode_steps")


def request_level() -> SAGAConfig:
    return SAGAConfig(cache_policy="none", enable_affinity=False,
                      enable_ttl=False, enable_prefetch=False,
                      enable_afs=False, enable_stealing=False,
                      observability="none")


def _sessions(smoke: bool):
    cfg = get_config("micro")
    n_steps = 3 if smoke else 5
    return runtime_requests(n_sessions=16, vocab=cfg.vocab, seed=SEED,
                            n_steps=n_steps, max_ctx=MAX_LEN - 32)


def run_policy(cfg, params, saga, reqs, engines=None, paged=True):
    """One runtime pass; returns (runtime, engine-counter deltas)."""
    rt = ServingRuntime(cfg, params, n_workers=N_WORKERS, saga=saga,
                        n_slots=N_SLOTS, max_len=MAX_LEN,
                        pool_blocks=POOL_BLOCKS, seed=SEED, perf=PERF,
                        engines=engines, paged=paged)
    before = {k: rt.stats()[k] for k in ENGINE_KEYS}
    for r in reqs:
        rt.submit(r)
    rt.run()
    rt.check_conservation()
    after = rt.stats()
    delta = {k: after[k] - before[k] for k in ENGINE_KEYS}
    return rt, delta


def run_ab(smoke: bool) -> dict:
    load_all()
    cfg = get_config("micro")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    reqs = _sessions(smoke)

    t0 = time.time()
    saga_rt, saga_eng = run_policy(cfg, params, SAGAConfig(), reqs)
    saga_wall = time.time() - t0
    saga = saga_rt.summarize()

    t0 = time.time()
    base_rt, base_eng = run_policy(cfg, params, request_level(), reqs,
                                   engines=saga_rt.engines)
    base_wall = time.time() - t0
    base_done = [s for s in base_rt.sessions.values()
                 if s.finished_at >= 0]
    base_tcts = sorted(s.tct for s in base_done)

    if not saga["regen_tokens"] < base_eng["regen_tokens"]:
        raise AssertionError(
            f"SAGA regen {saga['regen_tokens']} not strictly below "
            f"request-level {base_eng['regen_tokens']}")
    if base_rt.co.cache_hits != 0:
        raise AssertionError("request-level baseline hit cache")

    out = {
        "n_sessions": len(reqs),
        "n_engines": N_WORKERS,
        "saga": saga,
        "saga_wall_s": saga_wall,
        "reqlevel": {
            "regen_tokens": base_eng["regen_tokens"],
            "prefill_tokens": base_eng["prefill_tokens"],
            "decode_rounds": base_eng["decode_steps"],
            "tct_mean": sum(base_tcts) / len(base_tcts),
            "tct_p99": percentile(base_tcts, 0.99),
            "makespan": max(s.finished_at for s in base_done),
        },
        "reqlevel_wall_s": base_wall,
        "regen_reduction_x":
            base_eng["regen_tokens"] / max(saga["regen_tokens"], 1),
        "tct_speedup_x":
            (sum(base_tcts) / len(base_tcts)) / max(saga["tct_mean"],
                                                    1e-9),
    }
    emit("serve_saga", saga_wall,
         f"regen={saga['regen_tokens']} tct_mean={saga['tct_mean']:.3f} "
         f"hits={saga['cache_hits']} steals={saga['steals']}")
    emit("serve_reqlevel", base_wall,
         f"regen={base_eng['regen_tokens']} "
         f"tct_mean={out['reqlevel']['tct_mean']:.3f}")
    emit("serve_ab", saga_wall + base_wall,
         f"regen_reduction={out['regen_reduction_x']:.2f}x "
         f"tct_speedup={out['tct_speedup_x']:.2f}x")
    return out


def run_chaos(cfg, params) -> dict:
    """Chaos mode: the full SAGA stack under an engine fail / recover /
    scale-up plan on real engines.  Conservation (admitted == finished,
    zero slot/KV-block leak) is asserted inside ``run_policy`` via
    ``check_conservation``, exactly like the simulator's gate."""
    reqs = _sessions(smoke=True)
    rt = ServingRuntime(cfg, params, n_workers=N_WORKERS, saga=SAGAConfig(),
                        n_slots=N_SLOTS, max_len=MAX_LEN,
                        pool_blocks=POOL_BLOCKS, seed=SEED, perf=PERF,
                        fault_plan=chaos_plan(N_WORKERS, 30.0,
                                              n_events=12, seed=1))
    for r in reqs:
        rt.submit(r)
    rt.run()
    rt.check_conservation()      # raises on ANY unfinished session or
    rt.verify_pool_mirrors()     # slot/KV-block leak
    s = rt.summarize()
    if s["faults_injected"] < 1:
        raise AssertionError("chaos plan injected no engine failures")
    return s


def _starvation_runtimes(cfg, params, preempt: bool) -> ServingRuntime:
    """Two hog decodes hold the only engine's two slots; a
    higher-aggregate-demand burst of short sessions then arrives."""
    saga = SAGAConfig(enable_preemption=preempt)
    rt = ServingRuntime(cfg, params, n_workers=1, saga=saga, n_slots=2,
                        max_len=MAX_LEN, pool_blocks=POOL_BLOCKS,
                        seed=SEED, perf=RuntimePerf())
    rng = np.random.RandomState(3)
    for i in range(2):
        rt.submit(AgentRequest(
            f"hog{i}", "hogT",
            [(list(map(int, rng.randint(1, cfg.vocab, 8))), 150,
              "code_execution", 0.05)]))
    for i in range(8):
        rt.submit(AgentRequest(
            f"st{i}", "stT",
            [(list(map(int, rng.randint(1, cfg.vocab, 6))), 40,
              "web_api", 0.05)], arrival_s=0.2))
    rt.run()
    rt.check_conservation()
    return rt


def run_preemption_ab(cfg, params) -> dict:
    """AFS preemption gate: with preemption ON, running decodes are
    parked for the starved tenant and the max fair-share deviation
    (Thm. 2) must be strictly below admission-only ordering."""
    base = _starvation_runtimes(cfg, params, preempt=False)
    pre = _starvation_runtimes(cfg, params, preempt=True)
    if base.preempted != 0:
        raise AssertionError("admission-only run preempted")
    if pre.preempted < 1:
        raise AssertionError("preemption never fired in starvation mix")
    if not pre.afs_dev_max < base.afs_dev_max:
        raise AssertionError(
            f"preemption did not tighten AFS deviation: "
            f"{pre.afs_dev_max} vs admission-only {base.afs_dev_max}")
    return {
        "afs_dev_admission": base.afs_dev_max,
        "afs_dev_preempt": pre.afs_dev_max,
        "dev_reduction_x": base.afs_dev_max / pre.afs_dev_max,
        "preemptions": pre.preempted,
        "preempt_summary": pre.summarize(),
        "admission_summary": base.summarize(),
    }


def run_paged_gather_ab(cfg, params) -> dict:
    """Paged-vs-gather leg: the true-paged decode path (attend over
    block tables, metadata-only park/resume) against the gather oracle
    (contiguous slot caches, park/resume as real device copies).  Both
    must make bit-identical scheduling decisions AND emit bit-identical
    tokens — the whole summary repr matches — while paged moves zero
    park/resume device bytes and regenerates exactly the same tokens."""
    reqs = _sessions(smoke=True)
    t0 = time.time()
    paged_rt, paged_eng = run_policy(cfg, params, SAGAConfig(), reqs)
    paged_wall = time.time() - t0
    t0 = time.time()
    gather_rt, gather_eng = run_policy(cfg, params, SAGAConfig(), reqs,
                                       paged=False)
    gather_wall = time.time() - t0
    if repr(paged_rt.summarize()) != repr(gather_rt.summarize()):
        raise AssertionError(
            "paged and gather summaries diverged — the paged path "
            "changed scheduling decisions or token ids")
    if paged_eng["regen_tokens"] != gather_eng["regen_tokens"]:
        raise AssertionError(
            f"regen bytes changed: paged {paged_eng['regen_tokens']} vs "
            f"gather {gather_eng['regen_tokens']}")
    ps, gs = paged_rt.stats(), gather_rt.stats()
    if ps["park_copy_bytes"] != 0 or ps["resume_copy_bytes"] != 0:
        raise AssertionError(
            f"paged park/resume moved device bytes: "
            f"park={ps['park_copy_bytes']} resume={ps['resume_copy_bytes']}")
    if gs["park_copy_bytes"] <= 0 or gs["resume_copy_bytes"] <= 0:
        raise AssertionError("gather oracle moved no park/resume bytes "
                             "— the A/B is not exercising park/resume")
    rounds = max(paged_eng["decode_steps"], 1)
    # per-round wall is informational: whichever mode compiles first on
    # a cold jit cache absorbs its compile set (CI warms both via the
    # persistent compilation cache)
    out = {
        "paged_wall_s": paged_wall,
        "gather_wall_s": gather_wall,
        "decode_rounds": paged_eng["decode_steps"],
        "paged_us_per_round": 1e6 * paged_wall / rounds,
        "gather_us_per_round": 1e6 * gather_wall / rounds,
        "round_latency_delta_us":
            1e6 * (paged_wall - gather_wall) / rounds,
        "paged_park_copy_bytes": ps["park_copy_bytes"],
        "paged_resume_copy_bytes": ps["resume_copy_bytes"],
        "gather_park_copy_bytes": gs["park_copy_bytes"],
        "gather_resume_copy_bytes": gs["resume_copy_bytes"],
    }
    emit("serve_paged_round", paged_wall / rounds,
         f"gather={out['gather_us_per_round']:.0f}us "
         f"delta={out['round_latency_delta_us']:+.0f}us "
         f"park_bytes=0 resume_bytes=0 vs "
         f"{gs['park_copy_bytes']}/{gs['resume_copy_bytes']}")
    return out


def _disagg_arm(cfg, params, reqs, disagg: bool):
    """One traced arm of the disaggregation A/B.  Both arms run the
    same BurstGPT-style burst mix over the same engine count with the
    same chunked-prefill interference coefficients (both directions:
    prefills stretch co-resident decode rounds AND are themselves
    chunked into the round schedule) — the only difference is whether
    prefill work shares decode engines (unified) or lives in its own
    pool with block-granular handoff (disagg).  The mix is
    prefill-heavy (long agent contexts, short tool-step decodes), so
    the pool is provisioned to the prefill share of compute: 5 prefill
    / 3 decode engines — role sizing is a deployment choice, and an
    underprovisioned pool simply queues (``prefill_deferred``)."""
    perf = RuntimePerf(prefill_tokens_per_s=8000.0 / 64.0,
                       prefill_round_interference=0.35,
                       prefill_decode_interference=0.35)
    roles = [ROLE_PREFILL] * 5 + [ROLE_DECODE] * 3 if disagg else None
    rt = ServingRuntime(cfg, params, n_workers=DISAGG_WORKERS,
                        saga=SAGAConfig(disaggregate=disagg),
                        n_slots=6, max_len=MAX_LEN,
                        pool_blocks=POOL_BLOCKS, seed=SEED, perf=perf,
                        roles=roles, trace=True)
    for r in reqs:
        rt.submit(r)
    rt.run()
    rt.check_conservation()
    rt.verify_pool_mirrors()
    rt.tracer.check_closed()
    return rt, report(rt.tracer)


def _disagg_reqs(cfg):
    return runtime_requests(n_sessions=16, vocab=cfg.vocab, seed=SEED,
                            mix=("burstgpt",), n_steps=3,
                            max_ctx=MAX_LEN - 32)


def run_disagg_ab(cfg, params) -> dict:
    """Disaggregation gate: under a bursty mix where chunked prefill
    interferes with co-resident decode rounds
    (``prefill_round_interference`` > 0 in BOTH arms), splitting the
    engines into prefill/decode pools must improve TTFT-on-resume p99
    — resumes whose speculative prefill and handoff overlapped the tool
    gap join a decode slot with zero prefill on the critical path — and
    must not degrade p99 decode-round latency (prefill leaves the
    decode engines)."""
    uni_rt, uni = _disagg_arm(cfg, params, _disagg_reqs(cfg), False)
    dis_rt, dis = _disagg_arm(cfg, params, _disagg_reqs(cfg), True)
    ds = dis_rt.summarize()
    if ds["handoffs"] < 1 or ds["speculative_prefills"] < 1:
        raise AssertionError(
            f"disagg arm never exercised the handoff path: {ds}")
    uni_ttft = uni["ttft_on_resume"]["p99"]
    dis_ttft = dis["ttft_on_resume"]["p99"]
    if not dis_ttft < uni_ttft:
        raise AssertionError(
            f"disaggregation did not improve TTFT-on-resume p99: "
            f"{dis_ttft:.4f}s vs unified {uni_ttft:.4f}s")
    uni_round = uni["round_latency"]["p99"]
    dis_round = dis["round_latency"]["p99"]
    if not dis_round <= uni_round:
        raise AssertionError(
            f"disaggregation degraded p99 round latency: "
            f"{dis_round:.4f}s vs unified {uni_round:.4f}s")
    out = {
        "n_engines": DISAGG_WORKERS,
        "roles": list(dis_rt.roles),
        "unified_ttft_resume_p99": uni_ttft,
        "disagg_ttft_resume_p99": dis_ttft,
        "ttft_improvement_x": uni_ttft / max(dis_ttft, 1e-9),
        "unified_round_p99": uni_round,
        "disagg_round_p99": dis_round,
        "handoffs": ds["handoffs"],
        "handoff_bytes": ds["handoff_bytes"],
        "speculative_prefills": ds["speculative_prefills"],
        "prefill_deferred": ds["prefill_deferred"],
        "unified_summary": uni_rt.summarize(),
        "disagg_summary": ds,
    }
    emit("serve_disagg_ab", dis_ttft,
         f"ttft_resume_p99={dis_ttft:.4f}s vs {uni_ttft:.4f}s "
         f"({out['ttft_improvement_x']:.2f}x) round_p99="
         f"{dis_round:.4f}s vs {uni_round:.4f}s "
         f"handoffs={ds['handoffs']}")
    return out


def run_traced(cfg, params, expect_summary) -> dict:
    """Observability leg: the clean SAGA pass re-run with the span
    tracer on.  Tracing is read-only by contract, so the traced
    summary must be byte-identical to the untraced one from
    ``run_ab``; every span must close; and the Perfetto trace +
    per-phase TCT decomposition are saved for CI's artifact upload."""
    reqs = _sessions(smoke=True)
    rt = ServingRuntime(cfg, params, n_workers=N_WORKERS,
                        saga=SAGAConfig(), n_slots=N_SLOTS,
                        max_len=MAX_LEN, pool_blocks=POOL_BLOCKS,
                        seed=SEED, perf=PERF, trace=True)
    t0 = time.time()
    for r in reqs:
        rt.submit(r)
    rt.run()
    wall = time.time() - t0
    rt.check_conservation()
    if repr(rt.summarize()) != repr(expect_summary):
        raise AssertionError(
            "traced summary diverged from untraced — tracing perturbed "
            "the schedule, violating the zero-perturbation contract")
    rt.tracer.check_closed()
    save_json("serve_bench_trace", chrome_trace(rt.tracer,
                                                rt.obs_metrics))
    rep = report(rt.tracer)
    frac = rep["phase_frac"]
    emit("serve_traced", wall,
         f"spans={len(rt.tracer.spans)} "
         f"prefill={frac.get('prefill', 0.0):.3f} "
         f"decode={frac.get('decode', 0.0):.3f} "
         f"round_p99={rep['round_latency']['p99']:.4f}")
    return rep


def _fingerprint() -> str:
    """Deterministic SAGA-run summaries (fresh engines, fixed seed): the
    byte-identity contract compared across runs and processes, covering
    the clean, chaos, and preemption paths.  Reduced sizes so the smoke
    gate can afford to run it three times — the contract is about
    replay, not scale."""
    load_all()
    cfg = get_config("micro")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    reqs = runtime_requests(n_sessions=8, vocab=cfg.vocab, seed=SEED,
                            n_steps=2, max_ctx=MAX_LEN - 32)
    rt, _ = run_policy(cfg, params, SAGAConfig(), reqs)
    lines = ["clean " + repr(rt.summarize())]
    chaos_reqs = runtime_requests(n_sessions=6, vocab=cfg.vocab,
                                  seed=SEED, n_steps=2,
                                  max_ctx=MAX_LEN - 32)
    crt = ServingRuntime(cfg, params, n_workers=N_WORKERS,
                         saga=SAGAConfig(enable_preemption=True),
                         n_slots=2, max_len=MAX_LEN,
                         pool_blocks=POOL_BLOCKS, seed=SEED, perf=PERF,
                         fault_plan=chaos_plan(N_WORKERS, 10.0,
                                               n_events=8, seed=1))
    for r in chaos_reqs:
        crt.submit(r)
    crt.run()
    crt.check_conservation()
    lines.append("chaos+preempt " + repr(crt.summarize()))
    return "\n".join(lines)


def _disagg_fingerprint() -> str:
    """Disaggregated-mode determinism contract: a clean disagg run and
    a disagg run with the prefill engine dying mid-stream, both
    summarized — handoff placement, transfer windows and fault
    cancellation are RNG- and hash-order-free, so these lines are
    byte-identical across processes and ``PYTHONHASHSEED``."""
    load_all()
    cfg = get_config("micro")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))

    def _one(fault_plan=None):
        rt = ServingRuntime(cfg, params, n_workers=4,
                            saga=SAGAConfig(disaggregate=True),
                            n_slots=N_SLOTS, max_len=MAX_LEN,
                            pool_blocks=POOL_BLOCKS, seed=SEED,
                            perf=PERF, fault_plan=fault_plan)
        for r in runtime_requests(n_sessions=8, vocab=cfg.vocab,
                                  seed=SEED, mix=("burstgpt",),
                                  n_steps=2, max_ctx=MAX_LEN - 32):
            rt.submit(r)
        rt.run()
        rt.check_conservation()
        return repr(rt.summarize())

    return "disagg " + _one() + "\ndisagg-chaos " \
        + _one(fault_plan=[(0.5, "fail", 0), (2.0, "recover", 0)])


def _asyncio_fingerprint() -> str:
    """Fake-clock asyncio identity contract (serving/frontend): the
    wall-clock driver run under ``FakeClock`` pops the same event heap
    through the same handlers, so its ``summarize()`` must be
    byte-identical to the virtual-time clean run — pacing can throttle,
    never reorder.  Submissions go through the ``SagaClient`` facade to
    pin that path too."""
    import asyncio

    from repro.serving.client import SagaClient
    from repro.serving.frontend import AsyncServingDriver, FakeClock

    load_all()
    cfg = get_config("micro")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))

    def _reqs():
        return runtime_requests(n_sessions=8, vocab=cfg.vocab,
                                seed=SEED, n_steps=2,
                                max_ctx=MAX_LEN - 32)

    rt, _ = run_policy(cfg, params, SAGAConfig(), _reqs())
    virt = repr(rt.summarize())

    art = ServingRuntime(cfg, params, n_workers=N_WORKERS,
                         saga=SAGAConfig(), n_slots=N_SLOTS,
                         max_len=MAX_LEN, pool_blocks=POOL_BLOCKS,
                         seed=SEED, perf=PERF)
    drv = AsyncServingDriver(art, clock=FakeClock())
    client = SagaClient.for_driver(drv)
    for r in _reqs():
        client.submit(r)
    asyncio.run(drv.run())
    art.check_conservation()
    wall = repr(art.summarize())
    if wall != virt:
        raise AssertionError(
            "asyncio fake-clock summary diverged from virtual time:\n"
            f"  virtual {virt}\n  asyncio {wall}")
    return "asyncio " + wall


def smoke() -> None:
    """CI gate: 16 concurrent sessions over 2 engines on real forward
    passes — SAGA strictly below request-level regeneration; chaos-mode
    conservation + zero slot/KV leak under engine faults; preemption
    strictly tightening max AFS deviation vs admission-only; and
    byte-identical identical-seed summaries (clean + chaos + preempt)
    in-process and across PYTHONHASHSEED, with the fingerprint saved
    for CI's readable-diff step."""
    load_all()
    cfg = get_config("micro")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    out = run_ab(smoke=True)
    chaos = run_chaos(cfg, params)
    pre = run_preemption_ab(cfg, params)
    pg = run_paged_gather_ab(cfg, params)
    dz = run_disagg_ab(cfg, params)
    rep = run_traced(cfg, params, out["saga"])
    out["chaos"] = chaos
    out["preemption"] = pre
    out["paged_vs_gather"] = pg
    out["disagg_ab"] = dz
    out["trace_report"] = rep
    save_json("serve_bench_smoke", out)
    a = _fingerprint()
    assert a == _fingerprint(), "same-process summaries diverged"
    d = _disagg_fingerprint()
    assert d == _disagg_fingerprint(), \
        "same-process disagg summaries diverged"
    z = _asyncio_fingerprint()    # asserts asyncio == virtual inside
    outs = []
    for hashseed in ("0", "424242"):
        env = dict(os.environ)
        env["PYTHONHASHSEED"] = hashseed
        r = subprocess.run([sys.executable, __file__, "--smoke-emit"],
                           env=env, capture_output=True, text=True,
                           timeout=240)
        assert r.returncode == 0, r.stderr
        outs.append(r.stdout)
    assert outs[0] == outs[1], "cross-process summaries diverged"
    assert a + "\n" + d + "\n" + z + "\n" == outs[0], \
        "parent/child summaries diverged"
    save_fingerprint("serve_bench", a)
    save_fingerprint("serve_bench_disagg", d)
    save_fingerprint("serve_bench_asyncio", z)
    print(f"smoke ok: {out['n_sessions']} sessions / {out['n_engines']} "
          f"engines, regen {out['saga']['regen_tokens']} vs "
          f"{out['reqlevel']['regen_tokens']} "
          f"({out['regen_reduction_x']:.2f}x); chaos "
          f"faults={chaos['faults_injected']} "
          f"cancelled={chaos['cancelled_attempts']} conservation green; "
          f"preemption dev {pre['afs_dev_preempt']:.3f} vs "
          f"{pre['afs_dev_admission']:.3f} "
          f"({pre['dev_reduction_x']:.2f}x, {pre['preemptions']} parks); "
          f"paged==gather byte-identical, park/resume copies 0 vs "
          f"{pg['gather_park_copy_bytes']}/"
          f"{pg['gather_resume_copy_bytes']} bytes "
          f"(round delta {pg['round_latency_delta_us']:+.0f}us); "
          f"disagg ttft-on-resume p99 {dz['disagg_ttft_resume_p99']:.4f}s "
          f"vs unified {dz['unified_ttft_resume_p99']:.4f}s "
          f"({dz['ttft_improvement_x']:.2f}x, {dz['handoffs']} handoffs); "
          f"traced run byte-identical ({rep['span_counts']['session']} "
          f"session span trees closed); asyncio fake-clock replay "
          f"byte-identical; determinism green")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI gate: A/B + conservation + determinism")
    ap.add_argument("--smoke-emit", action="store_true",
                    help="internal: print the determinism fingerprint")
    args = ap.parse_args()
    if args.smoke_emit:
        print(_fingerprint())
        print(_disagg_fingerprint())
        print(_asyncio_fingerprint())
        return
    if args.smoke:
        smoke()
        return
    out = run_ab(smoke=False)
    save_json("serve_bench", out)
    print(f"SAGA:          regen={out['saga']['regen_tokens']:6d} tokens  "
          f"tct_mean={out['saga']['tct_mean']:.3f}s  "
          f"makespan={out['saga']['makespan']:.3f}s")
    print(f"request-level: regen={out['reqlevel']['regen_tokens']:6d} "
          f"tokens  tct_mean={out['reqlevel']['tct_mean']:.3f}s  "
          f"makespan={out['reqlevel']['makespan']:.3f}s")
    print(f"regen reduction {out['regen_reduction_x']:.2f}x, "
          f"TCT speedup {out['tct_speedup_x']:.2f}x on real forward "
          f"passes")


if __name__ == "__main__":
    main()
