"""Shared benchmark helpers: matrix runner, statistics, CSV output.

Every ``tableN_*.py`` prints ``name,us_per_call,derived`` CSV lines
(us_per_call = benchmark wall time; derived = the table's headline
numbers) and writes full JSON under benchmarks/results/.
"""
from __future__ import annotations

import json
import math
import os
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.cluster.perf import PerfModel
from repro.cluster.simulator import ClusterSim, SimPolicy, summarize
from repro.cluster.workload import burstgpt_workload, swebench_workload, \
    webarena_workload
# repo-wide percentile convention (xs[min(n-1, int(p*n))]) and the
# {n, mean, p50, p99, max} latency rollup — one home (repro.obs.export)
# so summarize(), the benches, and report() agree digit-for-digit
from repro.obs.export import latency_summary, percentile  # noqa: F401

RESULTS = Path(__file__).resolve().parent / "results"

# frozen calibration (see EXPERIMENTS.md §Calibration)
SWE_RATE = 5.0          # tasks/min, 16 workers
WEB_RATE = 8.0
BURST_LOAD = 0.18
N_WORKERS = 16


def workload(kind: str, n_tasks: int, seed: int, cv_scale: float = 1.0):
    if kind == "swebench":
        return swebench_workload(n_tasks=n_tasks, rate_per_min=SWE_RATE,
                                 seed=seed, cv_scale=cv_scale)
    if kind == "webarena":
        return webarena_workload(n_tasks=n_tasks, rate_per_min=WEB_RATE,
                                 seed=seed)
    if kind == "burstgpt":
        return burstgpt_workload(horizon_s=60.0 * n_tasks / 4.0, seed=seed,
                                 load_factor=BURST_LOAD)
    raise ValueError(kind)


def run_policy(policy: SimPolicy, tasks, seed: int = 0,
               perf: Optional[PerfModel] = None,
               n_workers: int = N_WORKERS, fault_plan=None) -> dict:
    sim = ClusterSim(tasks, policy, n_workers=n_workers, perf=perf,
                     seed=seed, fault_plan=fault_plan)
    sim.run(horizon_s=86400)
    out = summarize(sim)
    out["coordinator"] = {
        "steals": sim.co.stealer.steals,
        "preemptions": sim.co.afs.preemptions,
        "prefetch_issued": sim.co.prefetcher.issued,
        "prefetch_correct": sim.co.prefetcher.correct,
    }
    return out


def run_seeds(policy_fn: Callable[[], SimPolicy], kind: str, n_tasks: int,
              seeds: Sequence[int], perf: Optional[PerfModel] = None,
              cv_scale: float = 1.0) -> Dict[str, list]:
    """Repeated trials with different workload+sim seeds."""
    rows = []
    for s in seeds:
        tasks = workload(kind, n_tasks, seed=s, cv_scale=cv_scale)
        rows.append(run_policy(policy_fn(), tasks, seed=s, perf=perf))
    agg: Dict[str, list] = {}
    for r in rows:
        for k, v in r.items():
            if isinstance(v, (int, float)):
                agg.setdefault(k, []).append(float(v))
    agg["_rows"] = rows
    return agg


def mean_std(xs: Sequence[float]):
    xs = list(xs)
    m = sum(xs) / len(xs)
    if len(xs) < 2:
        return m, 0.0
    var = sum((x - m) ** 2 for x in xs) / (len(xs) - 1)
    return m, math.sqrt(var)


def welch_t(a: Sequence[float], b: Sequence[float]):
    """Welch's t-test; two-tailed p via numerical t-distribution CDF."""
    ma, sa = mean_std(a)
    mb, sb = mean_std(b)
    na, nb = len(a), len(b)
    va, vb = sa ** 2 / max(na, 1), sb ** 2 / max(nb, 1)
    denom = math.sqrt(va + vb) or 1e-12
    t = (ma - mb) / denom
    df = (va + vb) ** 2 / max(
        va ** 2 / max(na - 1, 1) + vb ** 2 / max(nb - 1, 1), 1e-12)
    df = max(df, 1.0)
    # numerical two-tailed p for Student t
    x = np.linspace(0, abs(t), 4000)
    pdf = (1 + x ** 2 / df) ** (-(df + 1) / 2)
    # normalization via B(1/2, df/2)
    norm = math.sqrt(df) * math.exp(
        math.lgamma(0.5) + math.lgamma(df / 2) - math.lgamma((df + 1) / 2))
    cdf_half = np.trapezoid(pdf, x) / norm
    p = max(0.0, 1.0 - 2 * cdf_half)
    return t, df, p


def stars(p: float) -> str:
    if p < 0.001:
        return "***"
    if p < 0.01:
        return "**"
    if p < 0.05:
        return "*"
    return ""


def geo_mean(xs: Sequence[float]) -> float:
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def emit(name: str, wall_s: float, derived: str) -> None:
    print(f"{name},{wall_s * 1e6:.0f},{derived}", flush=True)


def save_json(name: str, payload) -> None:
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{name}.json").write_text(json.dumps(payload, indent=1,
                                                     default=str))


EXPECTED = Path(__file__).resolve().parent / "expected"


def save_fingerprint(name: str, text: str) -> Path:
    """Write a smoke run's deterministic summary bytes to
    ``benchmarks/results/<name>_fingerprint.txt``.  CI diffs this
    against the committed twin in ``benchmarks/expected/`` so a
    determinism break surfaces as a readable unified diff of summary
    dicts, not just a nonzero exit.

    Written atomically (temp file + ``os.replace``): an interrupted
    smoke run must not leave a truncated fingerprint behind — that
    diffs as a baffling half-summary instead of a missing file."""
    RESULTS.mkdir(parents=True, exist_ok=True)
    p = RESULTS / f"{name}_fingerprint.txt"
    tmp = p.with_suffix(".txt.tmp")
    tmp.write_text(text if text.endswith("\n") else text + "\n")
    os.replace(tmp, p)
    return p
