"""Table 2: empirical competitive ratio vs Bélády's offline-optimal.

Replays SWE-bench / WebArena access traces (derived from the same
workload generators the cluster runs) through WA-LRU, LRU and
prefix-LRU at a capacity that reproduces the paper's contended-cache
regime, against the Bélády oracle.
"""
from __future__ import annotations

import time

from repro.cluster.perf import PerfModel
from repro.cluster.workload import swebench_workload, webarena_workload
from repro.core.aeg import AEG, ToolStats
from repro.core.belady import Access, BeladyOracle, competitive_ratio, \
    replay_policy
from repro.core.ttl import ToolTTLPolicy
from repro.core.walru import EvictionWeights, LRUCache, PrefixLRUCache, \
    WALRUCache

from benchmarks.common import emit, mean_std, save_json


def trace_from_tasks(tasks, kv_bytes_per_token: float):
    """Convert agent tasks into a single-worker cache access trace: each
    LLM step touches the session's cache at its (virtual) start time."""
    events = []
    for task in tasks:
        t = task.arrival_s
        for i, step in enumerate(task.steps):
            t += 0.5 + step.tool_latency_s
            ctx = task.context_before(i)
            events.append(Access(
                t=t, session=task.task_id, tokens=ctx,
                bytes_=ctx * kv_bytes_per_token, node_id=i,
                tool=step.tool, last=(i == task.n_steps - 1),
                prefix_tokens=task.prefix_tokens))
    events.sort(key=lambda a: a.t)
    return events


def trained_ttl(tasks) -> ToolTTLPolicy:
    """The deployed system learns per-tool latency distributions
    (Algorithm 1 line 1); pre-train from the trace's own history."""
    ttl = ToolTTLPolicy()
    for t in tasks:
        for st in t.steps:
            ttl.observe(st.tool, st.tool_latency_s)
    return ttl


def make_walru(capacity, tasks):
    stats = ToolStats()
    for t in tasks[:40]:
        for st in t.steps:
            stats.observe(st.tool, st.obs_tokens, st.tool_latency_s)
    aegs = {t.task_id: AEG.linear_chain(t.tools()) for t in tasks}
    lens = {t.task_id: t.n_steps for t in tasks}

    def p_reuse(entry):
        aeg = aegs.get(entry.session_id)
        if aeg is None or entry.node_id >= lens[entry.session_id] - 1:
            return 0.0
        return aeg.p_reuse(entry.node_id, entry.tokens, stats)

    return WALRUCache(capacity, EvictionWeights(), p_reuse_fn=p_reuse)


def run(seeds=(0, 1, 2), n_tasks=120):
    perf = PerfModel()
    results = {}
    for wl_name, gen, rate in [
            ("swebench", swebench_workload, 10.0),
            ("webarena", webarena_workload, 14.0)]:
        crs = {"walru": [], "lru": [], "prefix": []}
        for seed in seeds:
            tasks = gen(n_tasks=n_tasks, rate_per_min=rate, seed=seed)
            trace = trace_from_tasks(tasks, perf.kv_bytes_per_token)
            # capacity = 1.2x the peak concurrent LIVE set: enough for
            # active sessions plus headroom, so pressure comes from
            # completed-session clutter + long-idle tails — the regime
            # where workflow knowledge matters (paper §4.1) and where our
            # WA-LRU lands at the paper's 1.31x bound
            events = []
            cur_size = {}
            for a in trace:
                events.append((a.t, a.session,
                               0.0 if a.last else a.bytes_))
            live, peak = {}, 0.0
            for t, sid, b in events:
                if b == 0.0:
                    live.pop(sid, None)
                else:
                    live[sid] = b
                peak = max(peak, sum(live.values()))
            cap = 1.2 * peak
            opt = BeladyOracle(cap).replay(trace)
            ttl = trained_ttl(tasks)
            crs["walru"].append(competitive_ratio(
                replay_policy(trace, make_walru(cap, tasks),
                              ttl_policy=ttl), opt))
            crs["lru"].append(competitive_ratio(
                replay_policy(trace, LRUCache(cap)), opt))
            crs["prefix"].append(competitive_ratio(
                replay_policy(trace, PrefixLRUCache(cap)), opt))
        results[wl_name] = {k: mean_std(v) for k, v in crs.items()}
    return results


def main():
    t0 = time.time()
    res = run()
    save_json("table2_competitive_ratio", res)
    wall = time.time() - t0
    for wl, r in res.items():
        emit(f"table2/{wl}", wall / 2,
             f"CR walru={r['walru'][0]:.2f} lru={r['lru'][0]:.2f} "
             f"prefix={r['prefix'][0]:.2f} (paper: 1.31/2.84/1.97 swe)")
    mean_cr = (res["swebench"]["walru"][0] +
               res["webarena"]["walru"][0]) / 2
    emit("table2/mean_walru_cr", wall, f"{mean_cr:.2f} (paper 1.30)")


if __name__ == "__main__":
    main()
