"""Figure 1: (a) time-regenerating breakdown, (b) memory utilization,
(c) end-to-end latency normalized to inference-only ideal."""
from __future__ import annotations

import time

from repro.cluster import baselines as B

from benchmarks.common import emit, mean_std, run_seeds, save_json


def main():
    t0 = time.time()
    res = {}
    for name in ["vllm", "vllm_apc", "saga"]:
        res[name] = run_seeds(B.ALL_BASELINES[name], "swebench", 200,
                              seeds=(0, 1))
    wall = time.time() - t0
    out = {}
    for name, r in res.items():
        regen, _ = mean_std(r["regen_time_frac"])
        mem, _ = mean_std(r["mem_util"])
        tct, _ = mean_std(r["tct_mean"])
        ideal, _ = mean_std(r["ideal_mean"])
        out[name] = {"regen_frac": regen, "mem_util": mem,
                     "tct_over_ideal": tct / ideal}
    save_json("fig1_breakdown", out)
    emit("fig1a/regen_frac", wall / 3,
         f"vllm={out['vllm']['regen_frac']:.2f} (paper .38) "
         f"apc={out['vllm_apc']['regen_frac']:.2f} (paper .22) "
         f"saga={out['saga']['regen_frac']:.2f} (paper .08)")
    emit("fig1b/mem_util", wall / 3,
         f"vllm={out['vllm']['mem_util']:.2f} (paper .42) "
         f"apc={out['vllm_apc']['mem_util']:.2f} (paper .59) "
         f"saga={out['saga']['mem_util']:.2f} (paper .71)")
    emit("fig1c/tct_over_ideal", wall / 3,
         f"vllm={out['vllm']['tct_over_ideal']:.1f}x "
         f"apc={out['vllm_apc']['tct_over_ideal']:.1f}x "
         f"saga={out['saga']['tct_over_ideal']:.1f}x "
         f"(paper 6.0/3.5/1.5 vs inference-only)")


if __name__ == "__main__":
    main()
