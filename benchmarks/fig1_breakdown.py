"""Figure 1: (a) time-regenerating breakdown, (b) memory utilization,
(c) end-to-end latency normalized to inference-only ideal — plus the
span-level per-phase TCT decomposition (queue_wait / prefill / resume /
decode / tool_gap) from a traced simulator run per baseline, the
SAGA-vs-request-level A/B the paper's Fig. 1a tells in aggregate:
request-level burns its TCT re-prefilling (regeneration is attributed
to the prefill phase, backlog wait included), SAGA replaces it with
cheap delta-resume.

    PYTHONPATH=src:. python benchmarks/fig1_breakdown.py
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from repro.cluster import baselines as B
from repro.cluster.simulator import ClusterSim
from repro.obs.export import report

from benchmarks.common import (N_WORKERS, emit, mean_std, run_seeds,
                               save_json, workload)

N_TASKS = 100
BASELINES = ["vllm", "vllm_apc", "saga"]


def traced_phase_breakdown(name: str) -> dict:
    """One traced run per baseline: the span tree decomposes each
    task's completion time into phases; tracing is read-only, so this
    is the same schedule fig1a/b/c aggregate."""
    sim = ClusterSim(workload("swebench", N_TASKS, seed=0),
                     B.ALL_BASELINES[name](), n_workers=N_WORKERS,
                     seed=0, trace=True)
    sim.run(horizon_s=86400)
    sim.check_conservation()
    sim.tracer.check_closed()
    rep = report(sim.tracer)
    return {"phase_totals_s": rep["phase_totals_s"],
            "phase_frac": rep["phase_frac"],
            "ttft_on_resume": rep["ttft_on_resume"],
            "tct": rep["tct"]}


def main():
    t0 = time.time()
    res = {}
    for name in BASELINES:
        res[name] = run_seeds(B.ALL_BASELINES[name], "swebench", N_TASKS,
                              seeds=(0, 1))
    out = {}
    for name, r in res.items():
        regen, _ = mean_std(r["regen_time_frac"])
        mem, _ = mean_std(r["mem_util"])
        tct, _ = mean_std(r["tct_mean"])
        ideal, _ = mean_std(r["ideal_mean"])
        out[name] = {"regen_frac": regen, "mem_util": mem,
                     "tct_over_ideal": tct / ideal}
    phases = {name: traced_phase_breakdown(name) for name in BASELINES}
    for name in BASELINES:
        out[name]["phase_breakdown"] = phases[name]
    wall = time.time() - t0
    save_json("fig1_breakdown", out)
    emit("fig1a/regen_frac", wall / 4,
         f"vllm={out['vllm']['regen_frac']:.2f} (paper .38) "
         f"apc={out['vllm_apc']['regen_frac']:.2f} (paper .22) "
         f"saga={out['saga']['regen_frac']:.2f} (paper .08)")
    emit("fig1b/mem_util", wall / 4,
         f"vllm={out['vllm']['mem_util']:.2f} (paper .42) "
         f"apc={out['vllm_apc']['mem_util']:.2f} (paper .59) "
         f"saga={out['saga']['mem_util']:.2f} (paper .71)")
    emit("fig1c/tct_over_ideal", wall / 4,
         f"vllm={out['vllm']['tct_over_ideal']:.1f}x "
         f"apc={out['vllm_apc']['tct_over_ideal']:.1f}x "
         f"saga={out['saga']['tct_over_ideal']:.1f}x "
         f"(paper 6.0/3.5/1.5 vs inference-only)")
    vf, sf = phases["vllm"]["phase_frac"], phases["saga"]["phase_frac"]
    emit("fig1d/phase_frac", wall / 4,
         f"vllm: prefill={vf.get('prefill', 0.0):.3f} "
         f"decode={vf.get('decode', 0.0):.3f} | "
         f"saga: prefill={sf.get('prefill', 0.0):.3f} "
         f"resume={sf.get('resume', 0.0):.3f} "
         f"decode={sf.get('decode', 0.0):.3f}")


if __name__ == "__main__":
    main()
