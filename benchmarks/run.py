"""Benchmark orchestrator: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (see benchmarks/common.py)
and writes full JSON to benchmarks/results/.  Roofline rows come from
the dry-run artifacts (launch/dryrun.py must have run first; the repo
ships the baseline sweep results).
"""
from __future__ import annotations

import sys
import time
import traceback

MODULES = [
    "benchmarks.fig1_breakdown",
    "benchmarks.table2_competitive_ratio",
    "benchmarks.table3_end_to_end",
    "benchmarks.table4_ablation",
    "benchmarks.table5_pattern_inference",
    "benchmarks.table6_slo",
    "benchmarks.table7_overhead",
    "benchmarks.table8_strategy",
    "benchmarks.table9_sensitivity",
    "benchmarks.table10_tool_variance",
    "benchmarks.swap_analysis",
    "benchmarks.thm2_drift",
    "benchmarks.roofline",
]


def main() -> None:
    import importlib
    print("name,us_per_call,derived")
    t0 = time.time()
    failures = []
    for mod_name in MODULES:
        try:
            mod = importlib.import_module(mod_name)
            mod.main()
        except Exception:
            failures.append(mod_name)
            print(f"{mod_name},0,ERROR", flush=True)
            traceback.print_exc()
    print(f"benchmarks/total,{(time.time() - t0) * 1e6:.0f},"
          f"failures={len(failures)}")
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
