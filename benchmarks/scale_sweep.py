"""256-worker scale sweep: event-loop hot-path overhead + conservation.

Measurements:
  1. queue microbench — the per-worker pending-step queue under a
     recorded push/pop/steal op trace: heap (current) vs the legacy
     sort-per-enqueue list it replaced.
  2. full-simulator sweep — ClusterSim at 64/128/256 workers with
     arrival rate scaled to cluster size; reports wall seconds,
     events processed, and us/event.
  3. chaos conservation — the 256-worker run repeated under a random
     fail/recover/scale-up plan; asserts every admitted task finished
     exactly once and no KV/slot accounting leaked.
  4. epoch-tick A/B — the incremental epoch tick (indexed idle set,
     delta-updated AFS columns, numpy load vector) vs a faithful
     re-implementation of the PR-1 path (per-epoch O(n_workers) scans,
     invalidate-and-rebuild AFS columns), under clean AND adversarial
     (chaos + straggler + preemption-storm) load.
  5. adversarial conservation — stragglers and preemption storms on
     top of chaos at 256 workers; ``check_conservation`` gates it.

    PYTHONPATH=src:. python benchmarks/scale_sweep.py [--full]
    PYTHONPATH=src:. python benchmarks/scale_sweep.py --smoke   # CI job

CSV rows follow the house format: ``name,us_per_call,derived``.
"""
from __future__ import annotations

import argparse
import os
import random
import subprocess
import sys
import time
from typing import Dict, List, NamedTuple

import numpy as np

from repro.cluster import baselines as B
from repro.cluster.faults import chaos_plan, preemption_storm_plan, \
    straggler_plan
from repro.cluster.simulator import ClusterSim, StepJob, StepQueue, \
    _QueueView, summarize
from repro.cluster.workload import Task, scale_workload
from repro.core.afs import AFSScheduler

from benchmarks.common import emit, save_fingerprint, save_json


class LegacySortQueue:
    """The pre-heap queue: append + full sort on every enqueue,
    pop(0) on every dequeue.  Kept here (not in the simulator) purely
    as the benchmark baseline."""

    def __init__(self):
        self._items = []

    def __len__(self):
        return len(self._items)

    def push(self, prio, seq, job):
        self._items.append((prio, job.enqueued_at, seq, job))
        self._items.sort(key=lambda x: (x[0], x[1], x[2]))

    def peek(self):
        return self._items[0][3] if self._items else None

    def pop(self):
        return self._items.pop(0)[3] if self._items else None

    def remove(self, session_id):
        for k, (_, _, _, job) in enumerate(self._items):
            if job.task.task_id == session_id:
                self._items.pop(k)
                return job
        return None

    def drain(self):
        jobs = [j for _, _, _, j in self._items]
        jobs.sort(key=lambda j: (j.enqueued_at, j.task.task_id,
                                 j.step_idx))
        self._items.clear()
        return jobs

    def snapshot(self):
        return sorted((j.enqueued_at, j.task.task_id)
                      for _, _, _, j in self._items)


class _LegacyTaskCols(NamedTuple):
    deadlines: "np.ndarray"
    works: "np.ndarray"
    tenant_idx: "np.ndarray"
    names: List[str]
    row_of: Dict[str, int]


class LegacyAFSScheduler(AFSScheduler):
    """PR-1's cached-column AFS path, kept here (not in src) purely as
    the epoch-tick A/B baseline: columns are rebuilt with a Python loop
    whenever a task was admitted since the last epoch (invalidate-on-
    add), instead of being persistent delta-updated arrays."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self._cols = None

    def add_task(self, tp):
        self.tasks[tp.task_id] = tp
        from repro.core.afs import TenantState
        self.tenants.setdefault(tp.tenant, TenantState(tp.tenant))
        self._cols = None

    def finish_task(self, task_id):
        if self.tasks.pop(task_id, None) is not None:
            if self._cols is not None and task_id in self._cols.row_of:
                self._cols.works[self._cols.row_of[task_id]] = 0.0
            else:
                self._cols = None

    def note_service(self, tenant, gpu_seconds):
        from repro.core.afs import TenantState
        if tenant not in self.tenants:
            self.tenants[tenant] = TenantState(tenant)
            self._cols = None
        self.tenants[tenant].service_s += gpu_seconds

    def note_progress(self, task_id, work_done_s):
        t = self.tasks.get(task_id)
        if t:
            t.work_remain_s = max(0.0, t.work_remain_s - work_done_s)
            if self._cols is not None and task_id in self._cols.row_of:
                self._cols.works[self._cols.row_of[task_id]] = \
                    t.work_remain_s
            else:
                self._cols = None

    def recompute(self, now):
        if self.tasks:
            if self._cols is None:
                names = list(self.tenants)
                tidx = {k: i for i, k in enumerate(names)}
                self._cols = _LegacyTaskCols(
                    np.array([t.deadline for t in self.tasks.values()]),
                    np.array([t.work_remain_s
                              for t in self.tasks.values()]),
                    np.array([tidx[t.tenant]
                              for t in self.tasks.values()]),
                    names,
                    {k: i for i, k in enumerate(self.tasks)},
                )
            c = self._cols
            slack = np.maximum(c.deadlines - now, self.epoch_s)
            acc_v = np.bincount(c.tenant_idx, weights=c.works / slack,
                                minlength=len(c.names))
            acc = dict(zip(c.names, acc_v.tolist()))
        else:
            acc = dict.fromkeys(self.tenants, 0.0)
        return self._shares_from(acc, write=True)


class LegacyEpochSim(ClusterSim):
    """PR-1's epoch tick: a fresh Python load list, fresh queue views,
    a fresh alive list, and a full worker scan to refresh the stealer's
    idle state — every 100 ms — plus the invalidate-and-rebuild AFS.
    Steal execution and everything outside the tick use current code,
    so the A/B isolates the tick itself."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        legacy = LegacyAFSScheduler(self.co.cfg.epoch_s,
                                    self.co.cfg.preempt_block_s)
        self.co.afs = legacy    # before run(): nothing registered yet

    def _epoch_decide(self):
        loads = [w.load(self.perf.max_batch) for w in self.workers]
        if self.policy.saga.enable_stealing:
            queues = [_QueueView(w) for w in self.workers]
        else:
            queues = [[]] * len(self.workers)
        alive = [w.alive for w in self.workers]
        decision, _ = self.co.epoch_tick(self.now, loads, queues,
                                         alive=alive, scan_queues=True)
        return decision


class _EpochTimerMixin:
    """Accumulates wall time spent inside the epoch-tick decision."""
    epoch_time = 0.0
    epoch_calls = 0

    def _epoch_decide(self):
        t0 = time.perf_counter()
        d = super()._epoch_decide()
        self.epoch_time += time.perf_counter() - t0
        self.epoch_calls += 1
        return d


class TimedSim(_EpochTimerMixin, ClusterSim):
    pass


class TimedLegacySim(_EpochTimerMixin, LegacyEpochSim):
    pass


def adversarial_plan(n_workers: int, horizon_s: float, seed: int = 0):
    """Chaos + stragglers + preemption storms, merged and sorted."""
    plan = chaos_plan(n_workers, horizon_s=horizon_s * 0.7,
                      n_events=16, seed=seed + 1)
    plan += straggler_plan(n_workers, horizon_s=horizon_s * 0.8,
                           n_stragglers=max(2, n_workers // 32),
                           slow_for_s=horizon_s * 0.15, seed=seed + 2)
    plan += preemption_storm_plan(n_workers, horizon_s=horizon_s,
                                  n_storms=2, kill_frac=0.33,
                                  downtime_s=horizon_s * 0.08,
                                  seed=seed + 3)
    return sorted(plan)


def _op_trace(n_ops: int, depth: int, seed: int):
    """Representative op mix at a target queue depth: mostly pushes and
    pops, occasional mid-queue steals."""
    rng = random.Random(seed)
    ops, live = [], 0
    for i in range(n_ops):
        r = rng.random()
        if live < depth and (r < 0.5 or live == 0):
            ops.append(("push", rng.uniform(-5.0, 0.0), f"s{i}"))
            live += 1
        elif r < 0.95:
            ops.append(("pop", 0.0, ""))
            live -= 1
        else:
            ops.append(("steal", 0.0, f"s{rng.randrange(max(i, 1))}"))
    return ops


def _drive(queue_cls, ops):
    q = queue_cls()
    seq = 0
    t0 = time.perf_counter()
    for kind, prio, sid in ops:
        if kind == "push":
            task = Task(sid, "t", "bench", 0.0, [])
            q.push(prio, seq, StepJob(task, 0, float(seq)))
            seq += 1
        elif kind == "pop":
            q.pop()
        else:
            q.remove(sid)
    return time.perf_counter() - t0


def bench_queue_impls(n_ops=20000, seed=0):
    """Heap vs sort-per-enqueue across queue depths: the sort's O(q)
    re-key on every push makes it degrade linearly with depth."""
    rows = []
    for depth in (16, 128, 1024):
        ops = _op_trace(n_ops, depth, seed)
        t_heap = _drive(StepQueue, ops)
        t_sort = _drive(LegacySortQueue, ops)
        emit(f"scale/queue_d{depth}", t_heap / n_ops,
             f"heap={t_heap / n_ops * 1e6:.2f}us/op "
             f"sort={t_sort / n_ops * 1e6:.2f}us/op "
             f"speedup={t_sort / t_heap:.1f}x")
        rows.append({"depth": depth,
                     "heap_us_per_op": t_heap / n_ops * 1e6,
                     "sort_us_per_op": t_sort / n_ops * 1e6,
                     "speedup": t_sort / t_heap})
    return rows


def bench_sim_scale(n_workers: int, tasks_per_worker: float,
                    fault: bool = False, seed: int = 0,
                    queue_cls=None, pressured: bool = False,
                    tag_extra: str = "", repeats: int = 1):
    """One full-simulator point.  ``pressured`` shrinks the batch size
    and bursts all arrivals into the first minute so per-worker queues
    actually build (the regime the queue refactor targets);
    ``queue_cls`` swaps the pending-step queue implementation.
    ``repeats`` reruns the identical (deterministic) simulation and
    keeps the fastest wall time — best-of-N suppresses scheduler noise
    on shared machines."""
    from repro.cluster.perf import PerfModel
    horizon = 30.0 if pressured else 600.0
    if pressured:
        tasks_per_worker = max(tasks_per_worker, 24.0)
    if pressured and n_workers <= 16:
        # deep-queue regime: with few workers and serial decode the
        # per-worker backlog reaches ~tasks_per_worker, so queue-op cost
        # dominates per-event overhead instead of the O(n_workers)
        # epoch tick
        tasks_per_worker = max(tasks_per_worker, 192.0)
    tasks = scale_workload(n_workers, tasks_per_worker, seed=seed,
                           horizon_s=horizon)
    perf = PerfModel(max_batch=1) if pressured else None
    plan = chaos_plan(n_workers, horizon_s=400.0, n_events=24,
                      seed=seed + 1) if fault else None
    wall = float("inf")
    for _ in range(max(repeats, 1)):
        sim = ClusterSim(tasks, B.saga(), n_workers=n_workers, perf=perf,
                         seed=seed, fault_plan=plan)
        if queue_cls is not None:
            for ws in sim.workers:
                ws.queue = queue_cls()
        t0 = time.perf_counter()
        sim.run(horizon_s=86400)
        wall = min(wall, time.perf_counter() - t0)
    s = summarize(sim)
    assert s["n_tasks"] == len(tasks), \
        f"{len(tasks) - s['n_tasks']} tasks lost at {n_workers} workers"
    sim.check_conservation()
    tag = ("chaos" if fault else "clean") + tag_extra
    us_ev = wall / max(sim.events_processed, 1) * 1e6
    emit(f"scale/sim{n_workers}_{tag}", wall,
         f"events={sim.events_processed} {us_ev:.1f}us/event "
         f"tct={s['tct_mean']:.0f}s migr/task="
         f"{s['migrations_per_task']:.2f}")
    return {"n_workers": n_workers, "fault": fault, "tag": tag,
            "wall_s": wall, "events": sim.events_processed,
            "us_per_event": us_ev, "tct_mean": s["tct_mean"],
            "n_tasks": s["n_tasks"]}


def bench_epoch_ab(n_workers: int, tasks_per_worker: float = 1.5,
                   seed: int = 0, adversarial: bool = False,
                   repeats: int = 3):
    """Incremental vs PR-1 epoch tick, identical workload.  Reports
    us per epoch-tick decision for each and the speedup."""
    horizon = 600.0
    tasks = scale_workload(n_workers, tasks_per_worker, seed=seed,
                           horizon_s=horizon)
    plan = adversarial_plan(n_workers, horizon, seed=seed) \
        if adversarial else None
    rows = {}
    for tag, cls in (("incr", TimedSim), ("legacy", TimedLegacySim)):
        best = float("inf")
        for _ in range(max(repeats, 1)):
            sim = cls(tasks, B.saga(), n_workers=n_workers, seed=seed,
                      fault_plan=plan)
            sim.run(horizon_s=86400)
            if sim.epoch_calls and sim.epoch_time / sim.epoch_calls < best:
                best = sim.epoch_time / sim.epoch_calls
                kept = sim
        kept.check_conservation()
        rows[tag] = {"us_per_tick": best * 1e6,
                     "epochs": kept.epoch_calls,
                     "events": kept.events_processed}
    speedup = rows["legacy"]["us_per_tick"] / rows["incr"]["us_per_tick"]
    mode = "adversarial" if adversarial else "clean"
    emit(f"scale/epoch_tick_{n_workers}_{mode}",
         rows["incr"]["us_per_tick"] * 1e-6,
         f"incr={rows['incr']['us_per_tick']:.1f}us/tick "
         f"legacy={rows['legacy']['us_per_tick']:.1f}us/tick "
         f"speedup={speedup:.2f}x")
    return {"n_workers": n_workers, "mode": mode, "speedup": speedup,
            **{f"{k}_{m}": v for k, r in rows.items()
               for m, v in r.items()}}


def bench_adversarial(n_workers: int, tasks_per_worker: float = 1.5,
                      seed: int = 0):
    """Conservation + overhead under chaos + stragglers + preemption
    storms at cluster scale."""
    horizon = 600.0
    tasks = scale_workload(n_workers, tasks_per_worker, seed=seed,
                           horizon_s=horizon, burst_frac=0.3)
    plan = adversarial_plan(n_workers, horizon, seed=seed)
    sim = ClusterSim(tasks, B.saga(), n_workers=n_workers, seed=seed,
                     fault_plan=plan)
    t0 = time.perf_counter()
    sim.run(horizon_s=86400)
    wall = time.perf_counter() - t0
    sim.check_conservation()
    s = summarize(sim)
    assert s["n_tasks"] == len(tasks)
    us_ev = wall / max(sim.events_processed, 1) * 1e6
    emit(f"scale/sim{n_workers}_adversarial", wall,
         f"events={sim.events_processed} {us_ev:.1f}us/event "
         f"migr/task={s['migrations_per_task']:.2f}")
    return {"n_workers": n_workers, "tag": "adversarial", "wall_s": wall,
            "events": sim.events_processed, "us_per_event": us_ev,
            "n_tasks": s["n_tasks"]}


def _smoke_summary(n_workers: int = 32, seed: int = 0) -> str:
    """One deterministic adversarial run; the repr is the determinism
    fingerprint compared across runs and processes."""
    horizon = 240.0
    tasks = scale_workload(n_workers, 2.0, seed=seed, horizon_s=horizon,
                           burst_frac=0.4)
    plan = adversarial_plan(n_workers, horizon, seed=seed)
    sim = ClusterSim(tasks, B.saga(), n_workers=n_workers, seed=seed,
                     fault_plan=plan)
    sim.run(horizon_s=86400)
    sim.check_conservation()
    s = summarize(sim)
    assert s["n_tasks"] == len(tasks)
    return repr(s)


def smoke() -> None:
    """Fast CI gate: conservation under chaos + straggler + preemption
    storms, plus byte-identical dual-run summaries (in-process AND
    across processes with different PYTHONHASHSEED) so determinism
    breaks fail in CI, not in review."""
    bench_queue_impls(n_ops=2000)
    a = _smoke_summary()
    b = _smoke_summary()
    assert a == b, "same-process identical-seed runs diverged"
    outs = []
    for hashseed in ("0", "424242"):
        env = dict(os.environ)
        env["PYTHONHASHSEED"] = hashseed
        r = subprocess.run([sys.executable, __file__, "--smoke-emit"],
                           env=env, capture_output=True, text=True,
                           timeout=600)
        assert r.returncode == 0, r.stderr
        outs.append(r.stdout)
    assert outs[0] == outs[1], "cross-process summaries diverged"
    assert a + "\n" == outs[0], "parent/child summaries diverged"
    save_fingerprint("scale_sweep", a)
    ab = bench_epoch_ab(64, repeats=1)
    save_json("scale_sweep_smoke", {"epoch_ab": ab})
    print(f"smoke ok: conservation + determinism green, "
          f"epoch-tick speedup {ab['speedup']:.2f}x at 64 workers")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="also run 64/128-worker points")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI gate: conservation + determinism")
    ap.add_argument("--smoke-emit", action="store_true",
                    help="internal: print the smoke summary fingerprint")
    ap.add_argument("--tasks-per-worker", type=float, default=1.5)
    args = ap.parse_args()
    if args.smoke_emit:
        print(_smoke_summary())
        return
    if args.smoke:
        smoke()
        return
    out = {"queue": bench_queue_impls(), "sims": [], "epoch_ab": []}
    sizes = [64, 128, 256] if args.full else [256]
    for n in sizes:
        out["sims"].append(bench_sim_scale(n, args.tasks_per_worker))
    out["sims"].append(bench_sim_scale(256, args.tasks_per_worker,
                                       fault=True))
    out["sims"].append(bench_adversarial(256, args.tasks_per_worker))
    # epoch-tick A/B: the PR's headline — incremental vs PR-1 tick
    out["epoch_ab"].append(bench_epoch_ab(256, args.tasks_per_worker))
    out["epoch_ab"].append(bench_epoch_ab(256, args.tasks_per_worker,
                                          adversarial=True))
    # head-to-head under queue pressure: heap vs legacy sort-per-enqueue
    heap = bench_sim_scale(256, args.tasks_per_worker, pressured=True,
                           tag_extra="_pressure_heap", repeats=3)
    sort = bench_sim_scale(256, args.tasks_per_worker, pressured=True,
                           queue_cls=LegacySortQueue,
                           tag_extra="_pressure_sort", repeats=3)
    emit("scale/queue_swap_speedup", sort["wall_s"] - heap["wall_s"],
         f"heap={heap['us_per_event']:.1f}us/event "
         f"sort={sort['us_per_event']:.1f}us/event "
         f"speedup={sort['us_per_event'] / heap['us_per_event']:.2f}x")
    out["sims"] += [heap, sort]
    # deep-queue head-to-head (16 workers, backlog ~190/worker): the
    # regime where sort-per-enqueue degrades hardest
    dheap = bench_sim_scale(16, 0.0, pressured=True,
                            tag_extra="_deep_heap", repeats=3)
    dsort = bench_sim_scale(16, 0.0, pressured=True,
                            queue_cls=LegacySortQueue,
                            tag_extra="_deep_sort", repeats=3)
    emit("scale/queue_swap_deep", dsort["wall_s"] - dheap["wall_s"],
         f"heap={dheap['us_per_event']:.1f}us/event "
         f"sort={dsort['us_per_event']:.1f}us/event "
         f"speedup={dsort['us_per_event'] / dheap['us_per_event']:.2f}x")
    out["sims"] += [dheap, dsort]
    save_json("scale_sweep", out)


if __name__ == "__main__":
    main()
