"""256-worker scale sweep: event-loop hot-path overhead + conservation.

Three measurements:
  1. queue microbench — the per-worker pending-step queue under a
     recorded push/pop/steal op trace: heap (current) vs the legacy
     sort-per-enqueue list it replaced.
  2. full-simulator sweep — ClusterSim at 64/128/256 workers with
     arrival rate scaled to cluster size; reports wall seconds,
     events processed, and us/event.
  3. chaos conservation — the 256-worker run repeated under a random
     fail/recover/scale-up plan; asserts every admitted task finished
     exactly once and no KV/slot accounting leaked.

    PYTHONPATH=src:. python benchmarks/scale_sweep.py [--full]

CSV rows follow the house format: ``name,us_per_call,derived``.
"""
from __future__ import annotations

import argparse
import random
import time

from repro.cluster import baselines as B
from repro.cluster.faults import chaos_plan
from repro.cluster.simulator import ClusterSim, StepJob, StepQueue, \
    summarize
from repro.cluster.workload import Task, scale_workload

from benchmarks.common import emit, save_json


class LegacySortQueue:
    """The pre-heap queue: append + full sort on every enqueue,
    pop(0) on every dequeue.  Kept here (not in the simulator) purely
    as the benchmark baseline."""

    def __init__(self):
        self._items = []

    def __len__(self):
        return len(self._items)

    def push(self, prio, seq, job):
        self._items.append((prio, job.enqueued_at, seq, job))
        self._items.sort(key=lambda x: (x[0], x[1], x[2]))

    def peek(self):
        return self._items[0][3] if self._items else None

    def pop(self):
        return self._items.pop(0)[3] if self._items else None

    def remove(self, session_id):
        for k, (_, _, _, job) in enumerate(self._items):
            if job.task.task_id == session_id:
                self._items.pop(k)
                return job
        return None

    def drain(self):
        jobs = [j for _, _, _, j in self._items]
        jobs.sort(key=lambda j: (j.enqueued_at, j.task.task_id,
                                 j.step_idx))
        self._items.clear()
        return jobs

    def snapshot(self):
        return sorted((j.enqueued_at, j.task.task_id)
                      for _, _, _, j in self._items)


def _op_trace(n_ops: int, depth: int, seed: int):
    """Representative op mix at a target queue depth: mostly pushes and
    pops, occasional mid-queue steals."""
    rng = random.Random(seed)
    ops, live = [], 0
    for i in range(n_ops):
        r = rng.random()
        if live < depth and (r < 0.5 or live == 0):
            ops.append(("push", rng.uniform(-5.0, 0.0), f"s{i}"))
            live += 1
        elif r < 0.95:
            ops.append(("pop", 0.0, ""))
            live -= 1
        else:
            ops.append(("steal", 0.0, f"s{rng.randrange(max(i, 1))}"))
    return ops


def _drive(queue_cls, ops):
    q = queue_cls()
    seq = 0
    t0 = time.perf_counter()
    for kind, prio, sid in ops:
        if kind == "push":
            task = Task(sid, "t", "bench", 0.0, [])
            q.push(prio, seq, StepJob(task, 0, float(seq)))
            seq += 1
        elif kind == "pop":
            q.pop()
        else:
            q.remove(sid)
    return time.perf_counter() - t0


def bench_queue_impls(n_ops=20000, seed=0):
    """Heap vs sort-per-enqueue across queue depths: the sort's O(q)
    re-key on every push makes it degrade linearly with depth."""
    rows = []
    for depth in (16, 128, 1024):
        ops = _op_trace(n_ops, depth, seed)
        t_heap = _drive(StepQueue, ops)
        t_sort = _drive(LegacySortQueue, ops)
        emit(f"scale/queue_d{depth}", t_heap / n_ops,
             f"heap={t_heap / n_ops * 1e6:.2f}us/op "
             f"sort={t_sort / n_ops * 1e6:.2f}us/op "
             f"speedup={t_sort / t_heap:.1f}x")
        rows.append({"depth": depth,
                     "heap_us_per_op": t_heap / n_ops * 1e6,
                     "sort_us_per_op": t_sort / n_ops * 1e6,
                     "speedup": t_sort / t_heap})
    return rows


def bench_sim_scale(n_workers: int, tasks_per_worker: float,
                    fault: bool = False, seed: int = 0,
                    queue_cls=None, pressured: bool = False,
                    tag_extra: str = "", repeats: int = 1):
    """One full-simulator point.  ``pressured`` shrinks the batch size
    and bursts all arrivals into the first minute so per-worker queues
    actually build (the regime the queue refactor targets);
    ``queue_cls`` swaps the pending-step queue implementation.
    ``repeats`` reruns the identical (deterministic) simulation and
    keeps the fastest wall time — best-of-N suppresses scheduler noise
    on shared machines."""
    from repro.cluster.perf import PerfModel
    horizon = 30.0 if pressured else 600.0
    if pressured:
        tasks_per_worker = max(tasks_per_worker, 24.0)
    if pressured and n_workers <= 16:
        # deep-queue regime: with few workers and serial decode the
        # per-worker backlog reaches ~tasks_per_worker, so queue-op cost
        # dominates per-event overhead instead of the O(n_workers)
        # epoch tick
        tasks_per_worker = max(tasks_per_worker, 192.0)
    tasks = scale_workload(n_workers, tasks_per_worker, seed=seed,
                           horizon_s=horizon)
    perf = PerfModel(max_batch=1) if pressured else None
    plan = chaos_plan(n_workers, horizon_s=400.0, n_events=24,
                      seed=seed + 1) if fault else None
    wall = float("inf")
    for _ in range(max(repeats, 1)):
        sim = ClusterSim(tasks, B.saga(), n_workers=n_workers, perf=perf,
                         seed=seed, fault_plan=plan)
        if queue_cls is not None:
            for ws in sim.workers:
                ws.queue = queue_cls()
        t0 = time.perf_counter()
        sim.run(horizon_s=86400)
        wall = min(wall, time.perf_counter() - t0)
    s = summarize(sim)
    assert s["n_tasks"] == len(tasks), \
        f"{len(tasks) - s['n_tasks']} tasks lost at {n_workers} workers"
    sim.check_conservation()
    tag = ("chaos" if fault else "clean") + tag_extra
    us_ev = wall / max(sim.events_processed, 1) * 1e6
    emit(f"scale/sim{n_workers}_{tag}", wall,
         f"events={sim.events_processed} {us_ev:.1f}us/event "
         f"tct={s['tct_mean']:.0f}s migr/task="
         f"{s['migrations_per_task']:.2f}")
    return {"n_workers": n_workers, "fault": fault, "tag": tag,
            "wall_s": wall, "events": sim.events_processed,
            "us_per_event": us_ev, "tct_mean": s["tct_mean"],
            "n_tasks": s["n_tasks"]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="also run 64/128-worker points")
    ap.add_argument("--tasks-per-worker", type=float, default=1.5)
    args = ap.parse_args()
    out = {"queue": bench_queue_impls(), "sims": []}
    sizes = [64, 128, 256] if args.full else [256]
    for n in sizes:
        out["sims"].append(bench_sim_scale(n, args.tasks_per_worker))
    out["sims"].append(bench_sim_scale(256, args.tasks_per_worker,
                                       fault=True))
    # head-to-head under queue pressure: heap vs legacy sort-per-enqueue
    heap = bench_sim_scale(256, args.tasks_per_worker, pressured=True,
                           tag_extra="_pressure_heap", repeats=3)
    sort = bench_sim_scale(256, args.tasks_per_worker, pressured=True,
                           queue_cls=LegacySortQueue,
                           tag_extra="_pressure_sort", repeats=3)
    emit("scale/queue_swap_speedup", sort["wall_s"] - heap["wall_s"],
         f"heap={heap['us_per_event']:.1f}us/event "
         f"sort={sort['us_per_event']:.1f}us/event "
         f"speedup={sort['us_per_event'] / heap['us_per_event']:.2f}x")
    out["sims"] += [heap, sort]
    # deep-queue head-to-head (16 workers, backlog ~190/worker): the
    # regime where sort-per-enqueue degrades hardest
    dheap = bench_sim_scale(16, 0.0, pressured=True,
                            tag_extra="_deep_heap", repeats=3)
    dsort = bench_sim_scale(16, 0.0, pressured=True,
                            queue_cls=LegacySortQueue,
                            tag_extra="_deep_sort", repeats=3)
    emit("scale/queue_swap_deep", dsort["wall_s"] - dheap["wall_s"],
         f"heap={dheap['us_per_event']:.1f}us/event "
         f"sort={dsort['us_per_event']:.1f}us/event "
         f"speedup={dsort['us_per_event'] / dheap['us_per_event']:.2f}x")
    out["sims"] += [dheap, dsort]
    save_json("scale_sweep", out)


if __name__ == "__main__":
    main()
