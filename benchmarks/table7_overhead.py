"""Table 7: coordinator overhead — REAL wall-clock measurements of the
scheduling primitives at cluster scale (64 workers / 32 tenants), plus
migration statistics from the simulator."""
from __future__ import annotations

import random
import time

from repro.cluster import baselines as B
from repro.cluster.simulator import ClusterSim, summarize
from repro.core.afs import AFSScheduler, TaskProgress
from repro.core.coordinator import GlobalCoordinator, SAGAConfig
from repro.core.aeg import PatternInferencer

from benchmarks.common import (N_WORKERS, emit, mean_std, run_seeds,
                               save_json, workload)


def time_coordinator_cycle(n_workers=64, n_tenants=32, n_sessions=512,
                           iters=200):
    co = GlobalCoordinator(SAGAConfig(), n_workers, 150e9)
    rng = random.Random(0)
    for i in range(n_sessions):
        co.register_task(f"s{i}", f"tenant{i % n_tenants}",
                         ["code_execution"] * 10, deadline=1e5,
                         work_est_s=60.0, now=0.0)
        w = i % n_workers
        co.on_step_end(f"s{i}", w, 20000.0, 6e9, "code_execution",
                       float(i) / 100)
    loads = [rng.random() for _ in range(n_workers)]
    queues = [[(0.0, f"s{rng.randrange(n_sessions)}")]
              if rng.random() < 0.4 else [] for _ in range(n_workers)]
    samples = []
    for it in range(iters):
        t0 = time.perf_counter()
        co.epoch_tick(float(it), loads, queues)
        samples.append((time.perf_counter() - t0) * 1e3)
    samples.sort()
    return samples


def time_afs(n_tenants=32, tasks_per=8, iters=500):
    afs = AFSScheduler()
    for i in range(n_tenants * tasks_per):
        afs.add_task(TaskProgress(f"t{i}", f"ten{i % n_tenants}",
                                  deadline=1e4, work_remain_s=100.0))
    samples = []
    for it in range(iters):
        t0 = time.perf_counter()
        afs.recompute(float(it))
        samples.append((time.perf_counter() - t0) * 1e3)
    samples.sort()
    return samples


def time_aeg_construction(iters=300):
    inf = PatternInferencer(min_tasks=1)
    import random as _r
    rng = _r.Random(0)
    tools = ["code_execution", "file_operations", "web_api",
             "database_query"]
    for _ in range(200):
        inf.record_trace([rng.choice(tools) for _ in range(40)])
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        inf.infer(rng.choice(tools), n_more=16)
        samples.append((time.perf_counter() - t0) * 1e3)
    samples.sort()
    return samples


def time_trace_overhead(n_tasks=150):
    """Span-tracer overhead: the same identical-seed simulation run
    untraced and traced.  The zero-perturbation contract (summaries
    byte-identical) is asserted here on every bench run, and the
    recording cost itself becomes a table row."""
    walls, summaries, n_spans = {}, {}, 0
    # two timed repetitions per variant, best-of taken: the first
    # repetition pays allocator/caches warmup and would otherwise make
    # the untraced-first ordering look slower than tracing itself
    for rep in range(2):
        for traced in (False, True):
            sim = ClusterSim(workload("swebench", n_tasks, seed=0),
                             B.saga(), n_workers=N_WORKERS, seed=0,
                             trace=traced)
            t0 = time.perf_counter()
            sim.run(horizon_s=86400)
            wall = time.perf_counter() - t0
            walls[traced] = min(walls.get(traced, wall), wall)
            summaries[traced] = repr(summarize(sim))
            if traced:
                sim.tracer.check_closed()
                n_spans = len(sim.tracer.spans)
    if summaries[False] != summaries[True]:
        raise AssertionError("tracing perturbed the schedule — traced "
                             "and untraced summaries diverged")
    return {
        "untraced_s": walls[False],
        "traced_s": walls[True],
        "overhead_frac": walls[True] / max(walls[False], 1e-9) - 1.0,
        "n_spans": n_spans,
        "us_per_span": 1e6 * (walls[True] - walls[False])
            / max(n_spans, 1),
    }


def main():
    t0 = time.time()
    cyc = time_coordinator_cycle()
    afs = time_afs()
    aeg = time_aeg_construction()
    trace = time_trace_overhead()
    sim = run_seeds(B.saga, "swebench", 150, seeds=(0,))
    migr, _ = mean_std(sim["migrations_per_task"])
    out = {
        "coordinator_cycle_ms": {"mean": sum(cyc) / len(cyc),
                                 "p95": cyc[int(0.95 * len(cyc))]},
        "afs_ms": {"mean": sum(afs) / len(afs),
                   "p95": afs[int(0.95 * len(afs))]},
        "aeg_ms": {"mean": sum(aeg) / len(aeg),
                   "p95": aeg[int(0.95 * len(aeg))]},
        "migrations_per_task": migr,
        "trace_overhead": trace,
    }
    save_json("table7_overhead", out)
    wall = time.time() - t0
    emit("table7/coordinator_cycle", wall / 4,
         f"mean={out['coordinator_cycle_ms']['mean']:.2f}ms "
         f"p95={out['coordinator_cycle_ms']['p95']:.2f}ms "
         "(paper 12.3/28.7ms incl gRPC)")
    emit("table7/afs", wall / 4,
         f"mean={out['afs_ms']['mean']:.3f}ms (paper 3.1ms @32 tenants)")
    emit("table7/aeg_construction", wall / 4,
         f"mean={out['aeg_ms']['mean']:.3f}ms (paper 45.2ms w/ parsing)")
    emit("table7/migrations_per_task", wall / 4,
         f"{migr:.2f} (paper 2.3, migration 230ms/890ms modeled)")
    emit("table7/trace_overhead", trace["traced_s"],
         f"{trace['overhead_frac'] * 100:+.1f}% wall over untraced, "
         f"{trace['n_spans']} spans "
         f"({trace['us_per_span']:.1f}us/span), summaries "
         "byte-identical")


if __name__ == "__main__":
    main()
