"""Wall-clock soak harness for the asyncio serving front end.

Hundreds of concurrent agent sessions pushed through the REAL stack —
``SagaClient`` → ``AsyncServingDriver`` (wall clock, executor-threaded
engine steps) → ``ServingRuntime`` on jitted engines — while a live
``SagaHTTPProxy`` serves OpenAI-compatible completions (one streamed)
and a ``/metrics`` scrape on the side.  Arrivals are staggered in real
time, so the virtual schedule is built from wall-clock traffic, not a
pre-declared plan.

The harness exits 0 only when, after the last session completes:

  * ``check_conservation()``   — every session finished, zero slot leak,
                                 indices consistent;
  * ``audit_blocks()``         — every KV block on every engine is on
                                 the free list or in exactly one table
                                 (no leak, no double-release);
  * ``verify_pool_mirrors()``  — coordinator metadata matches the real
                                 block tables;
  * ``check_closed()``         — every tracer span closed.

    PYTHONPATH=src:. python benchmarks/soak_bench.py --smoke   # CI:
        >= 200 sessions, completes in well under 60 s wall
    PYTHONPATH=src:. python benchmarks/soak_bench.py \
        --sessions 1000 --spread-s 30                          # longer

CSV row: ``soak,us_per_session,derived`` (house format).
"""
from __future__ import annotations

import argparse
import asyncio
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax
import numpy as np

from repro.configs import get_config, load_all
from repro.core.coordinator import SAGAConfig
from repro.models import lm
from repro.serving.client import SagaClient
from repro.serving.frontend import AsyncServingDriver, SagaHTTPProxy
from repro.serving.runtime import AgentRequest, RuntimePerf, ServingRuntime

from benchmarks.common import emit, save_json

N_WORKERS = 3
N_SLOTS = 8
MAX_LEN = 128
POOL_BLOCKS = 768
SEED = 0
TOOLS = ("code_execution", "web_api", "file_operations", "browser")


def _requests(n: int, vocab: int, seed: int = SEED):
    """Small multi-step sessions across 8 tenants: big enough to park
    on tool gaps, small enough that N hundred of them finish in CI."""
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        n_steps = int(rng.randint(2, 4))
        steps = [(list(map(int, rng.randint(1, vocab, size=8))),
                  int(rng.randint(3, 7)), TOOLS[int(rng.randint(4))],
                  float(rng.uniform(0.05, 0.4)))
                 for _ in range(n_steps)]
        reqs.append(AgentRequest(f"soak{i}", f"tenant{i % 8}", steps))
    return reqs


async def _http(port: int, method: str, path: str, body=None,
                headers=None) -> tuple:
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    payload = json.dumps(body).encode() if body is not None else b""
    head = f"{method} {path} HTTP/1.1\r\nHost: soak\r\nConnection: close\r\n"
    for k, v in (headers or {}).items():
        head += f"{k}: {v}\r\n"
    head += f"Content-Length: {len(payload)}\r\n\r\n"
    writer.write(head.encode() + payload)
    await writer.drain()
    data = await reader.read()
    writer.close()
    status = int(data.split(b" ", 2)[1])
    return status, data


async def _soak(n_sessions: int, spread_s: float, time_scale: float,
                strategy: str) -> dict:
    load_all()
    cfg = get_config("micro")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rt = ServingRuntime(cfg, params, seed=SEED, n_workers=N_WORKERS,
                        n_slots=N_SLOTS, max_len=MAX_LEN,
                        pool_blocks=POOL_BLOCKS, saga=SAGAConfig(),
                        perf=RuntimePerf(prefill_tokens_per_s=8000.0 / 64),
                        trace=True)
    driver = AsyncServingDriver(rt, time_scale=time_scale, executor=True)
    client = SagaClient.for_driver(driver)
    proxy = await SagaHTTPProxy(driver, strategy=strategy).start()
    pump = asyncio.create_task(driver.serve_forever())
    t0 = time.time()

    # stagger submissions over ~spread_s of real wall clock
    reqs = _requests(n_sessions, cfg.vocab)
    handles = []
    batch = max(1, n_sessions // max(1, int(spread_s / 0.05)))
    for i, r in enumerate(reqs):
        handles.append(client.submit(r, slo=120.0))
        if (i + 1) % batch == 0:
            await asyncio.sleep(0.05)

    # live HTTP traffic while the fleet decodes: 4 plain completions
    # on one sticky session + 1 streamed, end-to-end through the proxy
    chat = {"model": "soak", "max_tokens": 5,
            "messages": [{"role": "user", "content": "soak probe alpha"},
                         {"role": "assistant", "content": "ack"},
                         {"role": "user", "content": "soak probe beta"}],
            "saga": {"tool_gap_s": 0.1, "step_tokens": 3}}
    http_ok = 0
    for i in range(4):
        status, raw = await _http(proxy.port, "POST",
                                  "/v1/chat/completions", chat,
                                  {"X-Session-Id": "soak-http",
                                   "X-Program-Id": "soak-prog"})
        assert status == 200, raw[:200]
        resp = json.loads(raw.split(b"\r\n\r\n", 1)[1])
        assert resp["choices"][0]["message"]["content"], resp
        http_ok += 1
    status, raw = await _http(proxy.port, "POST", "/v1/chat/completions",
                              dict(chat, stream=True),
                              {"X-Session-Id": "soak-http"})
    assert status == 200 and b"[DONE]" in raw, raw[:200]
    http_ok += 1
    status, metrics = await _http(proxy.port, "GET", "/metrics")
    assert status == 200
    for family in (b"saga_queue_depth", b"saga_kv_pool_blocks_used",
                   b"saga_afs_deviation_max", b"saga_kv_handoff_bytes"):
        assert family in metrics, f"/metrics missing {family}"

    await asyncio.gather(*(h.wait(timeout=300.0) for h in handles))
    # idle one pump cycle so trailing epoch ticks drain, then stop
    while rt.ev:
        await asyncio.sleep(0.02)
    driver.stop()
    await pump
    await proxy.stop()
    wall = time.time() - t0

    # -- the four leak gates --------------------------------------------
    rt.check_conservation()
    rt.verify_pool_mirrors()
    for w, eng in enumerate(rt.engines):
        problems = eng.pool.audit_blocks()
        assert not problems, f"engine {w} block audit: {problems[:3]}"
    rt.tracer.check_closed()

    summary = rt.summarize()
    assert summary["n_done"] == len(rt.sessions) >= n_sessions
    done_http = [t for t in proxy.tracker.finished
                 if t.client_session == "soak-http"]
    assert len(done_http) == http_ok
    return {
        "n_sessions": int(summary["n_done"]),
        "http_completions": http_ok,
        "wall_s": wall,
        "events": driver.wall_stats["events"],
        "max_lag_s": driver.wall_stats["max_lag_s"],
        "virtual_makespan_s": summary["makespan"],
        "decoded_tokens": summary["decoded_tokens"],
        "steals": summary["steals"],
        "preempt_phase_counts": proxy.tracker.phase_counts(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: 200+ sessions, <60s wall, zero leak")
    ap.add_argument("--sessions", type=int, default=400)
    ap.add_argument("--spread-s", type=float, default=10.0,
                    help="wall seconds to spread arrivals over")
    ap.add_argument("--time-scale", type=float, default=0.02,
                    help="wall seconds per virtual second")
    ap.add_argument("--strategy", default="least-loaded",
                    choices=("saga-affinity", "round-robin",
                             "least-loaded"))
    args = ap.parse_args()
    if args.smoke:
        args.sessions, args.spread_s = 200, 4.0
    out = asyncio.run(_soak(args.sessions, args.spread_s,
                            args.time_scale, args.strategy))
    save_json("soak_bench_smoke" if args.smoke else "soak_bench", out)
    emit("soak", out["wall_s"] / max(out["n_sessions"], 1),
         f"sessions={out['n_sessions']} http={out['http_completions']} "
         f"wall={out['wall_s']:.1f}s events={out['events']} "
         f"lag={out['max_lag_s']:.3f}s")
    print(f"soak ok: {out['n_sessions']} sessions "
          f"(+{out['http_completions']} HTTP completions through the "
          f"proxy) in {out['wall_s']:.1f}s wall / "
          f"{out['virtual_makespan_s']:.1f}s virtual, "
          f"{out['events']} events, max pacing lag "
          f"{out['max_lag_s']:.3f}s; conservation + block audit + pool "
          f"mirrors + span closure all green")


if __name__ == "__main__":
    main()
