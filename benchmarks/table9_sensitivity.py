"""Table 9: single-axis parameter sensitivity — max TCT deviation within
each tested range vs the defaults."""
from __future__ import annotations

import dataclasses
import time

from repro.cluster import baselines as B
from repro.cluster.simulator import ClusterSim, summarize
from repro.cluster.workload import swebench_workload

from benchmarks.common import emit, save_json

SWEEPS = {
    "alpha": [0.2, 0.4],
    "beta": [0.4, 0.6],
    "gamma": [0.1, 0.3],
    "theta": [0.6, 0.95],
    "th_low": [0.6, 0.8],
    "th_high": [0.85, 0.95],
    "t_idle_s": [0.05, 0.2],
    "r_max": [1.5, 3.0],
    "ttl_max_s": [120.0, 600.0],
    "theta_conf": [0.5, 0.9],
}
PAPER = {"alpha": "<5%", "beta": "<8%", "gamma": "<3%", "theta": "<5%",
         "th_low": "<4%", "th_high": "<6%", "t_idle_s": "<7%",
         "r_max": "<4%", "ttl_max_s": "<3%", "theta_conf": "<6%"}


def _tct(policy, tasks):
    sim = ClusterSim(tasks, policy, n_workers=16, seed=0)
    sim.run(horizon_s=86400)
    return summarize(sim)["tct_mean"]


def main():
    t0 = time.time()
    tasks = swebench_workload(n_tasks=150, rate_per_min=5.0, seed=0)
    base = _tct(B.saga(), tasks)
    rows = {"default": {"tct": base}}
    for param, values in SWEEPS.items():
        deltas = []
        for v in values:
            pol = B.saga()
            pol.saga = dataclasses.replace(pol.saga, **{param: v})
            tct = _tct(pol, tasks)
            deltas.append(abs(tct - base) / base * 100.0)
        rows[param] = {"range": values,
                       "max_tct_delta_pct": max(deltas)}
    save_json("table9_sensitivity", rows)
    wall = time.time() - t0
    worst = 0.0
    for param in SWEEPS:
        d = rows[param]["max_tct_delta_pct"]
        worst = max(worst, d)
        emit(f"table9/{param}", wall / len(SWEEPS),
             f"max_delta={d:.1f}% over {rows[param]['range']} "
             f"(paper {PAPER[param]})")
    emit("table9/single_axis_robustness", wall,
         f"worst={worst:.1f}% (paper: <=8%)")


if __name__ == "__main__":
    main()
