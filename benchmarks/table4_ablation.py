"""Table 4: ablation — full SAGA minus one component at a time.

Run in the paper's pressured regime (KV pool sized so idle caches
compete for space during tool calls) — otherwise the eviction-policy
components show no effect."""
from __future__ import annotations

import time

from repro.cluster import baselines as B
from repro.cluster.perf import PerfModel
from repro.cluster.simulator import ClusterSim, summarize
from repro.cluster.workload import swebench_workload

from benchmarks.common import emit, mean_std, save_json

DROPS = ["walru", "ttl", "prefetch", "affinity", "stealing", "afs"]
PAPER = {"walru": "+54%", "ttl": "+42%", "prefetch": "+19%",
         "affinity": "+96%", "stealing": "+31%", "afs": "+8%"}


def _run(policy, seeds):
    perf = PerfModel(kv_pool_bytes=45e9)      # pressured pool
    tcts = []
    for s in seeds:
        tasks = swebench_workload(n_tasks=200, rate_per_min=6.0, seed=s)
        sim = ClusterSim(tasks, policy, n_workers=16, perf=perf, seed=s)
        sim.run(horizon_s=86400)
        tcts.append(summarize(sim)["tct_mean"])
    return tcts


def main():
    t0 = time.time()
    seeds = (0, 1)
    full_tct, _ = mean_std(_run(B.saga(), seeds))
    rows = {"full": {"tct": full_tct, "delta": "-"}}
    for drop in DROPS:
        tct, std = mean_std(_run(B.saga_ablation(drop), seeds))
        delta = (tct - full_tct) / full_tct * 100.0
        rows[f"w/o {drop}"] = {"tct": tct, "std": std,
                               "delta": f"{delta:+.0f}%",
                               "paper": PAPER[drop]}
    save_json("table4_ablation", rows)
    wall = time.time() - t0
    for name, r in rows.items():
        d = f"tct={r['tct']:.0f}s delta={r['delta']}"
        if "paper" in r:
            d += f" (paper {r['paper']})"
        emit(f"table4/{name.replace(' ', '_')}", wall / 7, d)


if __name__ == "__main__":
    main()
