"""Branching-workflow A/B benchmark: workflow-aware vs request-level on
a retry-heavy AgentProgram mix (the unified submission API's CI gate).

Drives the cluster simulator with GRAPH AgentPrograms — SWE-bench-style
retry loops (``swebench_retry_programs``) plus WebArena-style
conditional nav-vs-form branches (``webarena_branch_programs``) — whose
branches actually execute via each program's seeded resolver, and whose
declared AEGs reach the coordinator at admission (tier-a).  Compares:

  * SAGA (workflow-aware: WA-LRU + TTL + affinity + stealing + AFS,
    taken-edge node advancement, Eq. 9 work re-estimation), vs
  * the request-level baseline (vLLM-style: no cache reuse, FCFS,
    blind to the declared graph).

The smoke gate asserts conservation for both, SAGA strictly ahead on
regeneration, and byte-identical identical-seed summaries in-process
AND across processes with different PYTHONHASHSEED — branch resolution
must not leak any nondeterminism into the schedule.

    PYTHONPATH=src:. python benchmarks/workflow_bench.py           # full
    PYTHONPATH=src:. python benchmarks/workflow_bench.py --smoke   # CI

CSV rows follow the house format: ``name,us_per_call,derived``.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from repro.cluster import baselines as B
from repro.cluster.simulator import ClusterSim, summarize
from repro.cluster.workload import (swebench_retry_programs,
                                    webarena_branch_programs)
from repro.obs.export import report

from benchmarks.common import emit, save_fingerprint, save_json

SEED = 0


def _mix(n_each: int, retry_p: float = 0.3):
    return (swebench_retry_programs(n_programs=n_each, seed=SEED,
                                    retry_p=retry_p) +
            webarena_branch_programs(n_programs=n_each, seed=SEED))


def _run(policy, n_each: int, n_workers: int, trace: bool = False):
    sim = ClusterSim(_mix(n_each), policy, n_workers=n_workers,
                     seed=SEED, trace=trace)
    sim.run(horizon_s=7.2e6)
    sim.check_conservation()
    return sim, summarize(sim)


def run_ab(n_each: int = 24, n_workers: int = 8) -> dict:
    # the saga leg runs traced: tracing is read-only (the fingerprint
    # below stays an untraced twin, and the traced/untraced summary
    # byte-identity is serve_bench's + tests/test_obs.py's gate), and
    # its span tree gives the per-phase TCT decomposition for free
    t0 = time.time()
    saga_sim, saga = _run(B.saga(), n_each, n_workers, trace=True)
    saga_wall = time.time() - t0
    saga_sim.tracer.check_closed()
    t0 = time.time()
    _, base = _run(B.vllm(), n_each, n_workers)
    base_wall = time.time() - t0

    paths = [saga_sim.tasks[p.program_id].path
             for p in _mix(n_each)]
    retries = sum(1 for pth in paths
                  for a, b in zip(pth, pth[1:]) if b <= a)
    if retries < 1:
        raise AssertionError("retry-heavy mix took no retry edges")
    if not saga["regen_tokens_total"] < base["regen_tokens_total"]:
        raise AssertionError(
            f"workflow-aware regen {saga['regen_tokens_total']} not "
            f"below request-level {base['regen_tokens_total']}")
    if base["cache_hit_rate"] != 0.0:
        raise AssertionError("request-level baseline hit cache")

    rep = report(saga_sim.tracer)
    out = {
        "n_programs": 2 * n_each,
        "n_workers": n_workers,
        "retry_edges_taken": retries,
        "steps_executed": sum(len(p) for p in paths),
        "saga": saga,
        "saga_phase_breakdown": {
            "phase_totals_s": rep["phase_totals_s"],
            "phase_frac": rep["phase_frac"],
            "ttft_on_resume": rep["ttft_on_resume"],
        },
        "reqlevel": base,
        "regen_reduction_x": base["regen_tokens_total"]
            / max(saga["regen_tokens_total"], 1e-9),
        "tct_speedup_x": base["tct_mean"] / max(saga["tct_mean"], 1e-9),
    }
    emit("workflow_saga", saga_wall,
         f"tct_mean={saga['tct_mean']:.2f} "
         f"hit={saga['cache_hit_rate']:.3f} retries={retries}")
    emit("workflow_reqlevel", base_wall,
         f"tct_mean={base['tct_mean']:.2f}")
    emit("workflow_ab", saga_wall + base_wall,
         f"regen_reduction={out['regen_reduction_x']:.2f}x "
         f"tct_speedup={out['tct_speedup_x']:.2f}x")
    frac = rep["phase_frac"]
    emit("workflow_phase_breakdown", saga_wall,
         f"prefill={frac.get('prefill', 0.0):.3f} "
         f"resume={frac.get('resume', 0.0):.3f} "
         f"decode={frac.get('decode', 0.0):.3f} "
         f"tool_gap={frac.get('tool_gap', 0.0):.3f}")
    return out


def _fingerprint(n_each: int = 12, n_workers: int = 4) -> str:
    """Identical-seed branching run: summary bytes + every taken path
    (the cross-process identity contract now covers branch resolution)."""
    sim, s = _run(B.saga(), n_each, n_workers)
    paths = [sim.tasks[p.program_id].path for p in _mix(n_each)]
    return repr(s) + "|" + repr(paths)


def smoke() -> None:
    out = run_ab(n_each=12, n_workers=4)
    a = _fingerprint()
    assert a == _fingerprint(), "same-process summaries diverged"
    outs = []
    for hashseed in ("0", "424242"):
        env = dict(os.environ)
        env["PYTHONHASHSEED"] = hashseed
        r = subprocess.run([sys.executable, __file__, "--smoke-emit"],
                           env=env, capture_output=True, text=True,
                           timeout=240)
        assert r.returncode == 0, r.stderr
        outs.append(r.stdout)
    assert outs[0] == outs[1], "cross-process summaries diverged"
    assert a + "\n" == outs[0], "parent/child summaries diverged"
    save_fingerprint("workflow_bench", a)
    save_json("workflow_bench_smoke", out)
    print(f"smoke ok: {out['n_programs']} branching programs, "
          f"{out['retry_edges_taken']} retry edges taken, regen "
          f"reduction {out['regen_reduction_x']:.2f}x, determinism "
          f"green")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI gate: A/B + conservation + determinism")
    ap.add_argument("--smoke-emit", action="store_true",
                    help="internal: print the determinism fingerprint")
    args = ap.parse_args()
    if args.smoke_emit:
        print(_fingerprint())
        return
    if args.smoke:
        smoke()
        return
    out = run_ab()
    save_json("workflow_bench", out)
    print(f"workflow-aware: tct_mean={out['saga']['tct_mean']:.2f}s "
          f"hit_rate={out['saga']['cache_hit_rate']:.3f}")
    print(f"request-level:  tct_mean={out['reqlevel']['tct_mean']:.2f}s")
    print(f"{out['retry_edges_taken']} retry edges taken over "
          f"{out['steps_executed']} executed steps; regen reduction "
          f"{out['regen_reduction_x']:.2f}x, TCT speedup "
          f"{out['tct_speedup_x']:.2f}x")


if __name__ == "__main__":
    main()
